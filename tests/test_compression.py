"""Compressed client deltas (core/compression.py + the quantized
aggregation path): spec parsing, the <= scale/2 round-trip bound under
adversarial magnitudes, wire-bytes accounting, engine-mode parity,
checkpoint compatibility, the fuzzer's quantized backend legs, the
theory-scored validator on quantized runs (plus the over-coarse
mutation smoke), and the 4-virtual-device sharded subprocess check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import _subproc
from repro.core.compression import (CompressionSpec, compress_flat,
                                    dequantize_chunked, quantize_chunked,
                                    resolve_compression, round_trip,
                                    topk_mask, wire_bytes)
from repro.fed import InvariantViolation


# -- spec parsing --------------------------------------------------------------

def test_resolve_name_roundtrip():
    for name in ("none", "bf16", "int8", "int8-topk",
                 "int8:chunk=1024,levels=63", "int8-topk:topk=0.05",
                 "int8:levels=1,chunk=4096"):
        spec = resolve_compression(name)
        assert resolve_compression(spec.name) == spec
    assert resolve_compression(None).kind == "none"
    spec = CompressionSpec(kind="int8", chunk=512)
    assert resolve_compression(spec) is spec


def test_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        resolve_compression("int4")
    with pytest.raises(ValueError):
        CompressionSpec(kind="int8", chunk=0)
    with pytest.raises(ValueError):
        CompressionSpec(kind="int8", levels=0)
    with pytest.raises(ValueError):
        CompressionSpec(kind="int8", levels=200)
    with pytest.raises(ValueError):
        CompressionSpec(kind="int8-topk", topk_frac=0.0)


# -- the quantization lattice --------------------------------------------------

def _check_bound(g, chunk, levels=127):
    """|round_trip(g) - g| <= scale/2 per element (zero chunks exact)."""
    g = jnp.asarray(g, jnp.float32)
    payload, scales = quantize_chunked(g, chunk=chunk, levels=levels)
    back = dequantize_chunked(payload, scales, chunk=chunk,
                              d=g.shape[1])
    per_elem = jnp.repeat(scales, chunk, axis=1)[:, :g.shape[1]]
    err = np.asarray(jnp.abs(back - g))
    bound = np.asarray(per_elem) / 2
    assert (err <= bound * (1 + 1e-5) + 1e-45).all(), \
        float((err - bound).max())
    return payload, scales, back


@settings(max_examples=10, deadline=None)
@given(K=st.integers(1, 5), D=st.integers(1, 700),
       chunk=st.sampled_from([32, 128, 256]),
       scale_pow=st.integers(-42, 18))
def test_roundtrip_error_bound_property(K, D, chunk, scale_pow):
    """Adversarial magnitudes: the per-element error bound must hold
    from deep-subnormal chunks up to 1e5-scale ones."""
    rng = np.random.default_rng(K * 100_000 + D * 13 + scale_pow)
    g = rng.normal(size=(K, D)) * float(2.0 ** scale_pow)
    _check_bound(g, chunk)


def test_roundtrip_subnormal_scale_chunk():
    # a normal absmax whose absmax/levels underflows into the subnormal
    # range (flushed to 0 on FTZ backends): the 2**-126 scale floor must
    # kick in and keep the lattice finite and within bound
    g = np.full((1, 128), 2e-38, np.float32)       # normal f32
    payload, scales, back = _check_bound(g, 64)
    assert np.isfinite(np.asarray(back)).all()
    assert (np.asarray(scales) >= np.float32(2.0 ** -126)).all()


def test_roundtrip_flushed_subnormal_chunk_is_zero():
    # inputs below the FTZ threshold read as absmax 0 inside XLA and are
    # treated as a zero chunk — scale 0, codes 0, no inf/nan blowup
    g = np.full((1, 128), 1e-40, np.float32)       # subnormal f32
    payload, scales = quantize_chunked(jnp.asarray(g), chunk=64)
    back = dequantize_chunked(payload, scales, chunk=64, d=128)
    assert np.isfinite(np.asarray(back)).all()
    assert int(np.abs(np.asarray(payload)).max()) == 0


def test_roundtrip_all_zero_chunk_is_exact():
    g = np.zeros((2, 256), np.float32)
    g[1, 128:] = 1.0                        # one live chunk alongside
    payload, scales, back = _check_bound(g, 128)
    assert float(np.asarray(scales)[0].max()) == 0.0
    np.testing.assert_array_equal(np.asarray(back[0]), g[0])


def test_roundtrip_single_outlier_chunk():
    # a 1e6 outlier dominates its chunk's absmax: neighbours collapse
    # toward 0 but must stay within scale/2 of themselves
    g = np.full((1, 256), 1e-6, np.float32)
    g[0, 7] = 1e6
    _check_bound(g, 256)


def test_quantize_respects_levels():
    g = np.random.default_rng(0).normal(size=(3, 512)).astype(np.float32)
    for levels in (1, 15, 127):
        payload, _ = quantize_chunked(jnp.asarray(g), chunk=128,
                                      levels=levels)
        assert payload.dtype == jnp.int8
        assert int(jnp.abs(payload).max()) <= levels


def test_topk_mask_keeps_largest():
    g = jnp.asarray(np.arange(1, 101, dtype=np.float32)[None, :]
                    * np.array([[1.0], [-1.0]], np.float32))
    keep = np.asarray(topk_mask(g, 0.1))
    # per row: exactly the 10 largest magnitudes survive (sign-blind)
    assert keep.sum() == 20
    assert keep[:, -10:].all() and not keep[:, :90].any()


def test_round_trip_kinds():
    g = jnp.asarray(np.random.default_rng(1).normal(size=(2, 300)),
                    jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(round_trip(g, resolve_compression("none"))),
        np.asarray(g))
    bf = round_trip(g, resolve_compression("bf16"))
    np.testing.assert_array_equal(
        np.asarray(bf), np.asarray(g.astype(jnp.bfloat16)
                                   .astype(jnp.float32)))
    with pytest.raises(ValueError):
        compress_flat(g, resolve_compression("bf16"))


def test_wire_bytes_accounting():
    for D in (610, 1_000_000):              # bench model and a big one
        none = wire_bytes(D, "none")
        assert none == 4 * D
        assert wire_bytes(D, "bf16") * 2 == none
        assert none / wire_bytes(D, "int8") >= 3.5   # acceptance floor
        assert (wire_bytes(D, "int8-topk") < wire_bytes(D, "int8")
                < wire_bytes(D, "bf16") < none)
    assert wire_bytes(100, "int8", n_clients=7) == \
        7 * wire_bytes(100, "int8")


# -- engine integration --------------------------------------------------------

def _make_sched(tmp=None, *, engine_mode="client_parallel",
                compression="int8", seed=0):
    from repro.configs.paper import SYNTHETIC_LR
    from repro.data import synthetic_federation
    from repro.fed import Client, StreamScheduler
    from repro.core.participation import TRACES
    from repro.models.small import init_small, make_loss_fn

    train, test = synthetic_federation(0.5, 0.5, 4, seed=seed)
    rng = np.random.default_rng(seed)
    clients = [Client(x=tr[0], y=tr[1],
                      trace=TRACES[rng.integers(0, 8)],
                      x_test=te[0], y_test=te[1])
               for tr, te in zip(train, test)]
    return StreamScheduler(
        clients=clients, init_params=init_small(
            jax.random.PRNGKey(0), SYNTHETIC_LR),
        loss_fn=make_loss_fn(SYNTHETIC_LR), capacity=5, max_samples=60,
        local_epochs=3, batch_size=10, scheme="C", eta0=0.5, seed=0,
        mode="device", chunk_size=4, engine_mode=engine_mode,
        compression=compression)


def test_quantized_modes_bit_identical():
    """The sharp invariant: both execution modes quantize on the same
    flat (C, D_total) layout, so int8 parallel == int8 sequential to
    the bit (not just allclose)."""
    par = _make_sched()
    seq = _make_sched(engine_mode="client_sequential")
    par.run(8, eval_every=4)
    seq.run(8, eval_every=4)
    for a, b in zip(jax.tree.leaves(par.params),
                    jax.tree.leaves(seq.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for h1, h2 in zip(par.history, seq.history):
        np.testing.assert_array_equal(np.asarray(h1.s),
                                      np.asarray(h2.s))


def test_checkpoint_carries_wire_format(tmp_path):
    from repro.configs.paper import SYNTHETIC_LR
    from repro.fed import StreamScheduler
    from repro.models.small import make_loss_fn

    sch = _make_sched()
    sch.run(4, eval_every=2)
    path = str(tmp_path / "ckpt")
    sch.save(path)
    assert sch.engine_config()["compression"] == "int8"

    res = StreamScheduler.restore(path,
                                  loss_fn=make_loss_fn(SYNTHETIC_LR))
    assert res.engine.compression.name == "int8"
    sch.run(4, eval_every=2)
    res.run(4, eval_every=2)
    for a, b in zip(jax.tree.leaves(sch.params),
                    jax.tree.leaves(res.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # reusing a warm engine on the wrong wire format must refuse
    other = _make_sched(compression="none")
    with pytest.raises(ValueError, match="compression"):
        StreamScheduler.restore(path, loss_fn=make_loss_fn(SYNTHETIC_LR),
                                engine=other.engine)


# -- fuzzer backend legs -------------------------------------------------------

@pytest.fixture(scope="module")
def quant_pool():
    from repro.fed import make_backend_pool
    return make_backend_pool(("client_parallel", "quantized",
                              "quantized_sequential"))


@pytest.mark.fuzz
def test_fuzz_quantized_legs_vs_f32(quant_pool):
    """int8 legs against the f32 reference: held to the measured
    cross-wire gate (divergence is chaotic, not additive — see
    fed/fuzz.py QUANT_VS_F32_ATOL), zero recompiles throughout."""
    from repro.fed import run_backend_matrix
    from repro.fed.fuzz import QUANT_VS_F32_ATOL
    agg = run_backend_matrix(range(2), pool=quant_pool)
    assert agg["cases"] == 2
    assert agg["backends"] == ["client_parallel", "quantized",
                               "quantized_sequential"]
    assert agg["max_param_err"] <= QUANT_VS_F32_ATOL


@pytest.mark.fuzz
def test_fuzz_quantized_same_wire_is_exact_law(quant_pool):
    """Same wire format on both layouts: re-reference the matrix at the
    quantized leg — quantized_sequential must then meet the exact-law
    tolerance (one lattice, one trajectory)."""
    from repro.fed import run_cross_backend_case
    pool = {k: quant_pool[k] for k in ("quantized",
                                       "quantized_sequential")}
    r = run_cross_backend_case(pool, 1, reference="quantized")
    assert r["max_param_err"] < 5e-4


# -- theory-scored validation --------------------------------------------------

@pytest.mark.fuzz
def test_validator_quantized_corpus_passes():
    """Quantized runs are held to the same Thm 3.1 envelope and Table-1
    ordering as f32 — a sane lattice perturbs below the bound's slack."""
    from repro.fed import validate_corpus
    for comp in ("int8", "bf16"):
        agg = validate_corpus(range(1), rounds=48, compression=comp)
        assert agg["cases"] == 1
        assert agg["max_margin"] <= 1.0


@pytest.mark.fuzz
def test_mutation_over_coarse_quantization_is_caught():
    """Acceptance criterion: an over-coarse lattice (1 level per chunk)
    injects enough quantization noise to destroy the debiased update's
    edge — the validator must trip the Table-1 scheme ordering."""
    from repro.fed import validate_corpus
    with pytest.raises(InvariantViolation) as ei:
        validate_corpus(range(1), rounds=48, compression="int8:levels=1")
    assert ei.value.invariant == "scheme-ordering"


# -- 4-virtual-device sharded path ---------------------------------------------

@pytest.fixture(scope="module")
def quant_sharded_check():
    """Run tests/_quant_sharded_check.py once under a 4-device mesh."""
    return _subproc.run_check("_quant_sharded_check.py")


def test_quant_sharded_kernel_psum(quant_sharded_check):
    r = quant_sharded_check
    assert r["n_devices"] == 4
    assert r["quant_kernel_err_kblock_None"] < 1e-4
    assert r["quant_kernel_err_kblock_8"] < 1e-4


def test_quant_sharded_scheduler_parity(quant_sharded_check):
    r = quant_sharded_check
    assert r["quant_parity_rounds"] == 12
    assert r["quant_parity_max_err"] < 3e-3


def test_quant_sharded_zero_recompile_churn(quant_sharded_check):
    r = quant_sharded_check
    assert r["recompiles_across_churn"] == 0
    assert r["events_applied"] >= 4
